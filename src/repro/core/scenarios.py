"""`ScenarioSet` batch API + the jit/vmap evaluation engine.

Scenarios are encoded struct-of-arrays: a placement mask over the
platform's egocentric primitives plus per-scenario knobs (compression,
fps_scale, WiFi MCS tier, upload duty / VAD gating, display brightness).
A `PlatformSpec` compiles — once, cached — into a single jitted
`jax.vmap` kernel that maps the whole batch to per-component loads,
delivered totals (incl. power-delivery losses) and uplink rates.  A full
16-placement x 8-compression x 6-fps DSE grid is then ONE device call
instead of ~768 Python evaluations with `float()` host round-trips.

    platform = aria2.aria2_platform()
    sset = ScenarioSet.grid()                    # 768 design points
    rep = evaluate(platform, sset)               # one vmap call
    rep.total_mw                                 # (768,)
    rep.category_breakdown()["wireless"]         # (768,)

Everything stays differentiable in theta, so calibration and sensitivity
run `jax.grad` straight through the batched evaluator.

`evaluate`/`evaluate_batched` are the jitted public entries;
`batched_fn(platform)` exposes the same vmapped kernel UNjitted so
larger programs (daysim's fused day-Pareto pipeline, its row stage)
can inline it into their own traced body instead of paying a separate
dispatch per call.
"""
from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass, field, replace as _dc_replace

import jax
import jax.numpy as jnp
import numpy as np

from .platform import PRIMITIVES, PlatformSpec

# WiFi MCS tiers: (name, energy-per-bit scale, link-maintenance scale)
# relative to the MCS8 calibration point. Lower-order modulations spend
# less energy per bit and idle cheaper; 256-QAM buys peak rate at a
# link-power premium.
MCS_TIERS = (
    ("mcs2_qpsk", 0.62, 0.82),
    ("mcs8_baseline", 1.00, 1.00),
    ("mcs11_256qam", 1.38, 1.17),
)
DEFAULT_MCS = 1                         # mcs8: the paper's operating point

_MCS_EBIT = np.array([t[1] for t in MCS_TIERS], np.float32)
_MCS_LINK = np.array([t[2] for t in MCS_TIERS], np.float32)

# default DSE grid axes (paper Fig 4 x Fig 6)
GRID_COMPRESSIONS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)
GRID_FPS_SCALES = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0)


def _unit_knob(name: str, value):
    """Validate a [0, 1] fraction knob (scalar or array).

    upload_duty / brightness are physical fractions; a negative duty
    silently produced negative WiFi power before this guard."""
    arr = np.asarray(value, np.float64)
    if arr.size and (np.any(arr < 0.0) or np.any(arr > 1.0)):
        raise ValueError(f"{name} must be within [0, 1], got "
                         f"{float(arr.min())}..{float(arr.max())}")
    return value


def all_placements(primitives=PRIMITIVES) -> tuple:
    """All 2^n on-device subsets, in the paper's sweep order (by size)."""
    out = []
    for r in range(len(primitives) + 1):
        out.extend(itertools.combinations(primitives, r))
    return tuple(out)


@dataclass(frozen=True)
class ScenarioSet:
    """Struct-of-arrays scenario batch (all arrays share leading dim N)."""
    placement: np.ndarray           # (N, n_primitives) 0/1 mask
    compression: np.ndarray         # (N,)
    fps_scale: np.ndarray           # (N,)
    mcs_tier: np.ndarray            # (N,) int index into MCS_TIERS
    upload_duty: np.ndarray         # (N,) fraction of time uplink streams
    brightness: np.ndarray          # (N,) display brightness 0..1
    names: tuple = ()
    primitives: tuple = PRIMITIVES

    def __len__(self) -> int:
        return int(self.placement.shape[0])

    def vec(self) -> dict:
        """The engine's batched knob vector (pytree of jnp arrays)."""
        return {
            "placement": jnp.asarray(self.placement, jnp.float32),
            "compression": jnp.asarray(self.compression, jnp.float32),
            "fps_scale": jnp.asarray(self.fps_scale, jnp.float32),
            "mcs_tier": jnp.asarray(self.mcs_tier, jnp.int32),
            "upload_duty": jnp.asarray(self.upload_duty, jnp.float32),
            "brightness": jnp.asarray(self.brightness, jnp.float32),
        }

    def on_device(self, i: int) -> tuple:
        return tuple(p for j, p in enumerate(self.primitives)
                     if self.placement[i, j] > 0.5)

    def label(self, i: int) -> str:
        if self.names and i < len(self.names) and self.names[i]:
            return self.names[i]
        return "+".join(self.on_device(i)) or "(none)"

    # -- constructors -------------------------------------------------------
    @classmethod
    def build(cls, rows: list, primitives=PRIMITIVES) -> "ScenarioSet":
        """rows: dicts with on_device/compression/fps_scale/... knobs."""
        n = len(rows)
        pl = np.zeros((n, len(primitives)), np.float32)
        comp = np.ones(n, np.float32)
        fps = np.ones(n, np.float32)
        mcs = np.full(n, DEFAULT_MCS, np.int32)
        duty = np.ones(n, np.float32)
        bright = np.zeros(n, np.float32)
        names = []
        for i, r in enumerate(rows):
            for p in r.get("on_device", ()):
                if p not in primitives:
                    raise ValueError(f"unknown primitive {p!r}; "
                                     f"one of {primitives}")
                pl[i, primitives.index(p)] = 1.0
            comp[i] = r.get("compression", 10.0)
            fps[i] = r.get("fps_scale", 1.0)
            tier = int(r.get("mcs_tier", DEFAULT_MCS))
            if not 0 <= tier < len(MCS_TIERS):
                raise ValueError(f"mcs_tier {tier} out of range "
                                 f"[0, {len(MCS_TIERS)})")
            mcs[i] = tier
            duty[i] = _unit_knob("upload_duty", r.get("upload_duty", 1.0))
            bright[i] = _unit_knob("brightness", r.get("brightness", 0.0))
            names.append(r.get("name", ""))
        return cls(pl, comp, fps, mcs, duty, bright, tuple(names),
                   primitives)

    @classmethod
    def from_scenarios(cls, scenarios, primitives=PRIMITIVES):
        """From legacy `aria2.Scenario` objects (the migration path)."""
        return cls.build([{
            "name": s.name, "on_device": s.on_device,
            "compression": s.compression, "fps_scale": s.fps_scale,
            "mcs_tier": getattr(s, "mcs_tier", DEFAULT_MCS),
            "upload_duty": getattr(s, "upload_duty", 1.0),
            "brightness": getattr(s, "brightness", 0.0),
        } for s in scenarios], primitives)

    @classmethod
    def grid(cls, placements=None, compressions=GRID_COMPRESSIONS,
             fps_scales=GRID_FPS_SCALES, mcs_tiers=(DEFAULT_MCS,),
             upload_duties=(1.0,), brightnesses=(0.0,),
             primitives=PRIMITIVES) -> "ScenarioSet":
        """Cartesian product over knob axes (placement outermost)."""
        placements = (all_placements(primitives) if placements is None
                      else tuple(placements))
        rows = [{"on_device": p, "compression": float(c),
                 "fps_scale": float(f), "mcs_tier": int(m),
                 "upload_duty": float(u), "brightness": float(b)}
                for p in placements for c in compressions
                for f in fps_scales for m in mcs_tiers
                for u in upload_duties for b in brightnesses]
        return cls.build(rows, primitives)

    def take(self, idx) -> "ScenarioSet":
        """Row subset (or reorder) by integer indices or a boolean mask
        (e.g. a Pareto front_mask), names included."""
        idx = np.asarray(idx)
        idx = (np.flatnonzero(idx) if idx.dtype == bool
               else idx.astype(np.int64))
        if idx.size and (idx.min() < -len(self) or idx.max() >= len(self)):
            raise IndexError(f"take indices out of range for "
                             f"{len(self)}-row ScenarioSet")
        names = tuple(self.names[i] for i in idx) if self.names else ()
        return _dc_replace(
            self, placement=self.placement[idx],
            compression=self.compression[idx],
            fps_scale=self.fps_scale[idx], mcs_tier=self.mcs_tier[idx],
            upload_duty=self.upload_duty[idx],
            brightness=self.brightness[idx], names=names)

    def pad(self, n_rows: int) -> "ScenarioSet":
        """Pad up to ``n_rows`` by repeating row 0 (canonical shape
        bucketing: the clone rows are valid scenarios, so validation
        and the row power stages stay total; callers mask them out by
        never indexing past the real rows).  No-op when already
        ``n_rows`` long."""
        n = len(self)
        if n_rows < n:
            raise ValueError(f"pad target {n_rows} < {n} real rows")
        if n_rows == n or n == 0:
            return self
        idx = np.concatenate([np.arange(n),
                              np.zeros(n_rows - n, np.int64)])
        padded = self.take(idx)
        if self.names:
            return _dc_replace(padded, names=tuple(self.names)
                               + ("",) * (n_rows - n))
        return padded

    def row_matrix(self) -> np.ndarray:
        """(N, n_prim + 5) float64 matrix of every knob column — the
        canonical row identity used for deduplication."""
        return np.column_stack([
            np.asarray(self.placement, np.float64),
            np.asarray(self.compression, np.float64),
            np.asarray(self.fps_scale, np.float64),
            np.asarray(self.mcs_tier, np.float64),
            np.asarray(self.upload_duty, np.float64),
            np.asarray(self.brightness, np.float64)])

    def dedupe(self) -> tuple:
        """(unique ScenarioSet, inverse indices): `inverse` maps every
        original row to its unique representative, so
        `evaluate(plat, unique).total_mw[inverse]` recovers the full
        batch from one call on the unique rows.  Batch-level dedup for
        sweeps that enumerate redundant grids; the daysim table
        precompute solves the same problem cross-call with its own
        keyed row cache (`daysim._ROW_CACHE`)."""
        _, first, inverse = np.unique(self.row_matrix(), axis=0,
                                      return_index=True,
                                      return_inverse=True)
        return self.take(first), inverse.reshape(-1)

    def with_knob(self, **arrays) -> "ScenarioSet":
        """Replace whole knob columns (broadcast scalars over N)."""
        n = len(self)
        if "mcs_tier" in arrays:
            tiers = np.asarray(arrays["mcs_tier"])
            if tiers.min() < 0 or tiers.max() >= len(MCS_TIERS):
                raise ValueError(f"mcs_tier out of range "
                                 f"[0, {len(MCS_TIERS)})")
        for knob in ("upload_duty", "brightness"):
            if knob in arrays:
                _unit_knob(knob, arrays[knob])
        upd = {k: np.broadcast_to(np.asarray(v, np.float32), (n,)).copy()
               if k != "mcs_tier"
               else np.broadcast_to(np.asarray(v, np.int32), (n,)).copy()
               for k, v in arrays.items()}
        return _dc_replace(self, **upd)


# ---------------------------------------------------------------------------
# derived per-scenario features feeding the load rules
# ---------------------------------------------------------------------------

@dataclass
class Features:
    """jnp scalars derived from one scenario's knobs (vmapped axis 0)."""
    vio: jnp.ndarray
    et: jnp.ndarray
    asr: jnp.ndarray
    ht: jnp.ndarray
    n_on: jnp.ndarray
    compression: jnp.ndarray
    fps_scale: jnp.ndarray
    fps_f: jnp.ndarray              # sensor static-power factor
    mbps: jnp.ndarray               # instantaneous uplink rate
    mbps_eff: jnp.ndarray           # duty-gated average uplink rate
    codec_raw: jnp.ndarray          # raw pixel rate entering the codec
    raw_visual: jnp.ndarray         # raw visual traffic (DRAM)
    isp_duty: jnp.ndarray
    duty_npu: jnp.ndarray           # placement-indexed sim duties feeding
    duty_dsp: jnp.ndarray           # the queue_mw_per_duty contention
    duty_dram: jnp.ndarray          # terms (queueing effects)
    upload_duty: jnp.ndarray
    brightness: jnp.ndarray
    mcs_ebit_scale: jnp.ndarray
    mcs_link_scale: jnp.ndarray
    r_npu_ht: float                 # platform GFLOP/s x primitive constants
    r_npu_et: float
    r_hwa_vio: float
    r_dsp_asr: float


def _features_core(platform: PlatformSpec, on, c, fs, duty, brightness,
                   duty_of, mcs_ebit, mcs_link) -> Features:
    """Shared knob->feature math of the hard and relaxed paths.

    `on` may be a hard 0/1 mask or relaxed Bernoulli probabilities; the
    arithmetic below is its multilinear extension, so binary inputs
    reproduce the int-indexed oracle bit for bit.  `duty_of` abstracts
    the placement-indexed duty-table lookup (hard `jnp.take` vs the
    relaxed multilinear interpolation)."""
    R = dict(platform.raw_mbps)
    rates = dict(platform.ip_rates)
    prim = platform.primitives
    vio = on[prim.index("vio")]
    et = on[prim.index("eye_tracking")]
    asr = on[prim.index("asr")]
    ht = on[prim.index("hand_tracking")]
    n_on = jnp.sum(on)
    fps_f = 0.35 + 0.65 / fs

    # outward GS cameras: consumed on-device by HT(+VIO), else offloaded
    gs_off = (1.0 - ht) * R["gs"] + ht * (1.0 - vio) * R["gs_vio_share"]
    visual_off = R["rgb"] + gs_off + (1.0 - et) * R["et"]
    mbps = (visual_off / (c * fs) + (1.0 - asr) * R["audio_opus"]
            + R["imu"] + R["aux"] + R["signals"] * n_on)
    codec_raw = visual_off / fs
    raw_visual = (R["rgb"] + R["gs"] + R["et"]) / fs

    return Features(
        vio=vio, et=et, asr=asr, ht=ht, n_on=n_on, compression=c,
        fps_scale=fs, fps_f=fps_f, mbps=mbps, mbps_eff=mbps * duty,
        codec_raw=codec_raw, raw_visual=raw_visual,
        isp_duty=duty_of("isp", 1.0),
        duty_npu=duty_of("npu", 0.0), duty_dsp=duty_of("dsp", 0.0),
        duty_dram=duty_of("dram_bus", 0.0),
        upload_duty=duty, brightness=brightness,
        mcs_ebit_scale=mcs_ebit, mcs_link_scale=mcs_link,
        r_npu_ht=rates.get("npu_ht", 0.0), r_npu_et=rates.get("npu_et", 0.0),
        r_hwa_vio=rates.get("hwa_vio", 0.0),
        r_dsp_asr=rates.get("dsp_asr", 0.0))


def _features(platform: PlatformSpec, vec: dict, th: dict) -> Features:
    """Int-indexed feature path (the parity oracle's engine)."""
    prim = platform.primitives
    on = vec["placement"]
    # placement-mask index -> per-resource duty from the event-driven
    # taskgraph sim (ISP duty rule + NPU/DSP/DRAM contention terms)
    bits = jnp.asarray([1 << i for i in range(len(prim))], jnp.float32)
    idx = jnp.round(jnp.sum(on * bits)).astype(jnp.int32)

    def duty_of(resource, default):
        tab = platform.duty_table(resource, default)
        return jnp.take(jnp.asarray(tab, jnp.float32), idx)

    mcs = vec["mcs_tier"]
    return _features_core(
        platform, on, vec["compression"], vec["fps_scale"],
        vec["upload_duty"], vec["brightness"], duty_of,
        jnp.take(jnp.asarray(_MCS_EBIT), mcs),
        jnp.take(jnp.asarray(_MCS_LINK), mcs))


def _features_relaxed(platform: PlatformSpec, vec: dict,
                      th: dict) -> Features:
    """Differentiable feature path over relaxed (soft) discrete knobs.

    `placement` holds per-primitive on-device probabilities; the
    placement-indexed duty tables are interpolated multilinearly — the
    exact expectation over the product-Bernoulli placement distribution,
    which reduces to plain indexing at binary probabilities.  MCS scales
    are mixed by `mcs_weights` (one-hot == `jnp.take`)."""
    prim = platform.primitives
    on = vec["placement"]
    # (2^n, n) mask enumeration in placement-index order
    masks = jnp.asarray([[idx >> j & 1 for j in range(len(prim))]
                         for idx in range(1 << len(prim))],
                        jnp.result_type(float))
    w = jnp.prod(on * masks + (1.0 - on) * (1.0 - masks), axis=-1)

    def duty_of(resource, default):
        tab = platform.duty_table(resource, default)
        return w @ jnp.asarray(tab)

    mw = vec["mcs_weights"]
    return _features_core(
        platform, on, vec["compression"], vec["fps_scale"],
        vec["upload_duty"], vec["brightness"], duty_of,
        mw @ jnp.asarray(_MCS_EBIT), mw @ jnp.asarray(_MCS_LINK))


# ---------------------------------------------------------------------------
# load-rule implementations (platform.LOAD_KIND_NAMES)
# ---------------------------------------------------------------------------

LOAD_KINDS = {
    "const": lambda p, f, th: jnp.asarray(p["mw"], jnp.float32),
    "sensor_fps": lambda p, f, th: p["mw"] * f.fps_f,
    "isp": lambda p, f, th: (p["active_mw"] * f.isp_duty
                             / jnp.maximum(f.fps_scale, 1.0)
                             + p["floor_mw"]),
    "codec": lambda p, f, th: (th["codec_mw_per_rawmbps"] * f.codec_raw
                               + p["floor_mw"]),
    "dsp_audio": lambda p, f, th: (p["base_mw"]
                                   + f.asr * f.r_dsp_asr * th["pj_asr"]
                                   + (1.0 - f.asr) * p["idle_mw"]
                                   + th["queue_mw_per_duty"] * f.duty_dsp),
    "npu": lambda p, f, th: _npu(p, f, th),
    "hwa_vio": lambda p, f, th: (f.vio * (th["ip_idle_mw"]
                                          + f.r_hwa_vio * th["pj_vio"])
                                 + (1.0 - f.vio) * p["off_mw"]),
    "dram": lambda p, f, th: (p["base_mw"]
                              + th["dram_mw_per_mbps"] * f.raw_visual / 8.0
                              + th["queue_mw_per_duty"] * f.duty_dram
                              / jnp.maximum(f.fps_scale, 1.0)),
    "wifi": lambda p, f, th: (th["wifi_link_mw"] * f.mcs_link_scale
                              + th["wifi_mw_per_mbps"] * f.mcs_ebit_scale
                              * f.mbps_eff),
    "display": lambda p, f, th: p["base_mw"] + p["max_mw"] * f.brightness,
}


def _npu(p, f, th):
    any_on = jnp.maximum(f.ht, f.et)
    active = (th["ip_idle_mw"] + f.ht * f.r_npu_ht * th["pj_ht"]
              + f.et * f.r_npu_et * th["pj_et"])
    # queueing overhead: frame-driven NPU duty from the taskgraph sim
    # (shared by HT + ET nets), scaled down with the frame rate
    queue = th["queue_mw_per_duty"] * f.duty_npu \
        / jnp.maximum(f.fps_scale, 1.0)
    return any_on * active + (1.0 - any_on) * p["off_mw"] + queue


# ---------------------------------------------------------------------------
# compiled batch engine (one per platform, cached)
# ---------------------------------------------------------------------------

ENGINE_AXES = {"placement": 0, "compression": 0, "fps_scale": 0,
               "mcs_tier": 0, "upload_duty": 0, "brightness": 0}


@functools.lru_cache(maxsize=32)
def batched_fn(platform: PlatformSpec):
    """UNJITTED vmapped engine core for one platform.

    The returned `fn(vec, th) -> {"loads", "pd_loss", "total", "mbps"}`
    is jit-composable: callers may inline it inside a larger jitted
    program (the daysim fused day pipeline traces it between the row
    gather and the day scan so tables never leave the device), or wrap
    it in their own `jax.jit` — `_engine` below is exactly that wrapper.
    Both paths trace the SAME closure, so row values agree bit for bit
    up to XLA fusion context."""
    comps = platform.components
    rails = platform.rail_dict()
    rail_eff = np.array([rails[c.rail] for c in comps], np.float32)
    rules = [(LOAD_KINDS[c.load.kind], c.load.p()) for c in comps]

    def single(vec, th):
        f = _features(platform, vec, th)
        loads = jnp.stack([fn(p, f, th) for fn, p in rules])
        eff = jnp.minimum(jnp.asarray(rail_eff) * th["eff_scale"], 0.97)
        delivered = loads / eff
        return {"loads": loads, "pd_loss": jnp.sum(delivered - loads),
                "total": jnp.sum(delivered), "mbps": f.mbps_eff}

    return jax.vmap(single, in_axes=(ENGINE_AXES, None))


@functools.lru_cache(maxsize=32)
def _engine(platform: PlatformSpec):
    return jax.jit(batched_fn(platform))


def evaluate_batched(platform: PlatformSpec, vec: dict, theta=None) -> dict:
    """Jit-composable batch evaluation on raw knob vectors.

    Unlike `evaluate` (which round-trips through ScenarioSet/BatchReport
    and is an un-composable jit boundary), this takes the knob-vector
    pytree directly (see `ScenarioSet.vec`) and returns device arrays
    {"loads": (N, C), "pd_loss": (N,), "total": (N,), "mbps": (N,)}.
    Safe to call under an enclosing `jax.jit` trace with traced `vec` /
    `theta` leaves."""
    return batched_fn(platform)(vec, _theta(platform, theta))


def _single_relaxed(platform: PlatformSpec, vec: dict, th: dict) -> dict:
    """One relaxed design point -> loads/total/mbps (unjitted symbolic
    core shared by the batched engine and the daysim gradient path)."""
    comps = platform.components
    rails = platform.rail_dict()
    rail_eff = np.array([rails[c.rail] for c in comps], np.float32)
    f = _features_relaxed(platform, vec, th)
    loads = jnp.stack([LOAD_KINDS[c.load.kind](c.load.p(), f, th)
                       for c in comps])
    eff = jnp.minimum(jnp.asarray(rail_eff) * th["eff_scale"], 0.97)
    delivered = loads / eff
    return {"loads": loads, "pd_loss": jnp.sum(delivered - loads),
            "total": jnp.sum(delivered), "mbps": f.mbps_eff}


RELAXED_AXES = {"placement": 0, "compression": 0, "fps_scale": 0,
                "mcs_weights": 0, "upload_duty": 0, "brightness": 0}


@functools.lru_cache(maxsize=32)
def _engine_relaxed(platform: PlatformSpec):
    def single(vec, th):
        return _single_relaxed(platform, vec, th)
    return jax.jit(jax.vmap(single, in_axes=(RELAXED_AXES, None)))


def _theta(platform: PlatformSpec, theta=None) -> dict:
    th = platform.theta_dict()
    if theta:
        th.update(theta)
    return {k: jnp.asarray(v, jnp.float32) for k, v in th.items()}


@dataclass
class BatchReport:
    """Batched evaluation result; all arrays have leading dim N."""
    platform: PlatformSpec
    sset: ScenarioSet
    loads_mw: jnp.ndarray           # (N, n_components)
    total_mw: jnp.ndarray           # (N,)
    pd_loss_mw: jnp.ndarray         # (N,)
    offloaded_mbps: jnp.ndarray     # (N,)

    def category_breakdown(self) -> dict:
        """category -> (N,) mW; PD losses land under "power" (Fig 3)."""
        out: dict[str, jnp.ndarray] = {}
        cats = np.array([c.category for c in self.platform.components])
        for cat in sorted(set(cats)):
            mask = jnp.asarray((cats == cat).astype(np.float32))
            out[cat] = self.loads_mw @ mask
        out["power"] = out.get("power", 0.0) + self.pd_loss_mw
        return out

    def pd_share(self) -> jnp.ndarray:
        return self.pd_loss_mw / self.total_mw

    def component_loads(self, i: int) -> dict:
        names = self.platform.component_names()
        row = np.asarray(self.loads_mw[i])
        return dict(zip(names, row.tolist()))

    def rows(self) -> list:
        """Host-side summary rows (one `float()` sync for the whole batch)."""
        total = np.asarray(self.total_mw)
        mbps = np.asarray(self.offloaded_mbps)
        return [{"name": self.sset.label(i),
                 "on_device": "+".join(self.sset.on_device(i)) or "(none)",
                 "compression": float(self.sset.compression[i]),
                 "fps_scale": float(self.sset.fps_scale[i]),
                 "total_mw": float(total[i]),
                 "offload_mbps": float(mbps[i])}
                for i in range(len(self.sset))]


def _validate(platform: PlatformSpec, sset: ScenarioSet) -> None:
    if sset.primitives != platform.primitives:
        raise ValueError(
            f"ScenarioSet primitives {sset.primitives} do not match "
            f"platform {platform.name!r} primitives {platform.primitives}")
    supported = set(platform.supported_primitives())
    for j, p in enumerate(platform.primitives):
        if p not in supported and np.any(np.asarray(sset.placement)[:, j]):
            raise ValueError(
                f"platform {platform.name!r} cannot run {p!r} on-device "
                f"(its accelerator was dropped from the component table); "
                f"supported: {sorted(supported)}")


def evaluate(platform: PlatformSpec, sset: ScenarioSet,
             theta=None) -> BatchReport:
    """Evaluate the whole scenario batch in one jitted vmap call."""
    _validate(platform, sset)
    out = _engine(platform)(sset.vec(), _theta(platform, theta))
    return BatchReport(platform, sset, out["loads"], out["total"],
                       out["pd_loss"], out["mbps"])


def total_mw(platform: PlatformSpec, sset: ScenarioSet, theta=None):
    """(N,) delivered system power; differentiable in theta."""
    _validate(platform, sset)
    out = _engine(platform)(sset.vec(), _theta(platform, theta))
    return out["total"]


def component_loads(platform: PlatformSpec, sset: ScenarioSet, theta=None):
    """(N, n_components) component loads (pre-PD), names aligned."""
    _validate(platform, sset)
    out = _engine(platform)(sset.vec(), _theta(platform, theta))
    return out["loads"]


def offloaded_mbps(platform: PlatformSpec, sset: ScenarioSet, theta=None):
    """(N,) duty-gated average uplink rate."""
    _validate(platform, sset)
    out = _engine(platform)(sset.vec(), _theta(platform, theta))
    return out["mbps"]


def category_breakdown(platform: PlatformSpec, sset: ScenarioSet,
                       theta=None) -> dict:
    return evaluate(platform, sset, theta).category_breakdown()


# ---------------------------------------------------------------------------
# relaxed (differentiable-in-every-knob) evaluation
# ---------------------------------------------------------------------------

def relax_vec(sset: ScenarioSet) -> dict:
    """ScenarioSet -> relaxed knob vector (hard rows as a special case).

    Placement becomes float probabilities (0/1 for a hard set), the MCS
    tier becomes a one-hot weight row — at these values the relaxed
    engine reproduces `evaluate` exactly, which is the parity contract
    tests/test_design_grad.py asserts."""
    dt = np.float64 if jax.config.jax_enable_x64 else np.float32
    return {
        "placement": jnp.asarray(sset.placement, dt),
        "compression": jnp.asarray(sset.compression, dt),
        "fps_scale": jnp.asarray(sset.fps_scale, dt),
        "upload_duty": jnp.asarray(sset.upload_duty, dt),
        "brightness": jnp.asarray(sset.brightness, dt),
        "mcs_weights": jnp.asarray(
            np.eye(len(MCS_TIERS), dtype=dt)[np.asarray(sset.mcs_tier)]),
    }


def _validate_relaxed(platform: PlatformSpec, vec: dict) -> None:
    missing = set(RELAXED_AXES) - set(vec)
    if missing:
        raise ValueError(f"relaxed vec missing knobs {sorted(missing)}")
    n_prim = len(platform.primitives)
    if vec["placement"].shape[-1] != n_prim:
        raise ValueError(
            f"placement last dim {vec['placement'].shape[-1]} != "
            f"platform {platform.name!r} primitive count {n_prim}")
    if vec["mcs_weights"].shape[-1] != len(MCS_TIERS):
        raise ValueError(f"mcs_weights last dim must be {len(MCS_TIERS)}")


def evaluate_relaxed(platform: PlatformSpec, vec: dict,
                     theta=None) -> dict:
    """Batched relaxed evaluation: one jitted vmap call, differentiable
    in EVERY knob (placement probabilities, compression, fps, duty,
    brightness, MCS weights) as well as theta.

    `vec` is the relaxed knob pytree (see `relax_vec` /
    `design.device_vec`), all leaves sharing leading dim N.  Returns
    {"loads": (N, C), "total": (N,), "pd_loss": (N,), "mbps": (N,)}.
    """
    _validate_relaxed(platform, vec)
    return _engine_relaxed(platform)(vec, _theta_relaxed(platform, theta))


def _theta_relaxed(platform: PlatformSpec, theta=None) -> dict:
    """Theta merge that PRESERVES traced/64-bit leaves (unlike `_theta`,
    which casts to float32 — fine for the data path, fatal for x64
    finite-difference checks)."""
    th = {k: jnp.asarray(v) for k, v in platform.theta_dict().items()}
    if theta:
        th.update({k: jnp.asarray(v) for k, v in theta.items()})
    return th


def total_mw_relaxed(platform: PlatformSpec, vec: dict, theta=None):
    """(N,) delivered totals; `jax.grad`/`jax.vjp` flow through every
    knob leaf — the substrate for `dse.sensitivity_map` and
    `dse.gradient_descend`."""
    return evaluate_relaxed(platform, vec, theta)["total"]
