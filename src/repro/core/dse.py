"""Design-space exploration (§V-B, §VI-B) on the batched scenario engine.

Every sweep below builds ONE `ScenarioSet` and evaluates it through a
single jitted `jax.vmap` device call (scenarios.evaluate) — no Python
per-point loops or `float()` host round-trips on the hot path.

Paper sweeps:
  * placement_sweep      — all 2^4 on/off-device primitive placements
                           (Fig 4 shows 6 of them; we evaluate all 16).
  * compression_sweep    — compression {1..128} x fps {1..32} on the
                           full-offload configuration (Fig 6).

Beyond-paper:
  * grid_sweep           — the full placement x compression x fps grid
                           (>= 768 points) in one call, any platform.
  * sensitivity          — d(total power)/d(theta) via jax.grad through
                           the batched evaluator.
  * pareto               — placement x compression grid -> (power,
                           offload-bandwidth) Pareto front: bandwidth is a
                           proxy for backend context fidelity.
  * joint_pareto         — the paper's Amdahl lesson applied end to end:
                           placement x compression x fps x MCS swept in
                           ONE batched device call, each point's
                           offloaded streams mapped to per-stream backend
                           pod counts (offload.pods_breakdown, capacities
                           from the cached CapacityTable), and the
                           3-objective (device mW, uplink Mbps, backend
                           pods) non-dominated front extracted by the
                           blockwise numpy dominance pass.
  * co_optimize          — constrained argmins over the joint grid: min
                           device power under a backend pod budget, and
                           min pods under a device power budget.

All dominance filtering goes through `non_dominated` — the correct
Pareto test (<= in every objective, < in at least one), so points that
tie on one objective at better cost in another are kept.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from . import aria2, design, offload, scenarios
from .aria2 import PRIMITIVES, Scenario
from .design import DesignSpace
from .platform import PlatformSpec, diff as platform_diff
from .scenarios import MCS_TIERS, ScenarioSet, all_placements


def _plat(platform: PlatformSpec | str | None) -> PlatformSpec:
    if platform is None:
        return aria2.aria2_platform()
    if isinstance(platform, str):
        from . import platform as registry
        aria2.platforms()          # ensure built-ins registered
        return registry.get(platform)
    return platform


def grid_sweep(platform=None, placements=None,
               compressions=scenarios.GRID_COMPRESSIONS,
               fps_scales=scenarios.GRID_FPS_SCALES,
               **knobs) -> scenarios.BatchReport:
    """Full DSE grid (default 16 x 8 x 6 = 768 points) in one device call.

    Default placements are every subset of the primitives the platform
    can actually run on-device (reduced SKUs sweep a smaller grid)."""
    plat = _plat(platform)
    if placements is None:
        placements = all_placements(plat.supported_primitives())
    sset = ScenarioSet.grid(placements=placements,
                            compressions=compressions,
                            fps_scales=fps_scales,
                            primitives=plat.primitives, **knobs)
    return scenarios.evaluate(plat, sset)


def placement_sweep(platform=None):
    plat = _plat(platform)
    subsets = all_placements(plat.supported_primitives())
    sset = ScenarioSet.grid(placements=subsets, compressions=(10.0,),
                            fps_scales=(1.0,), primitives=plat.primitives)
    rep = scenarios.evaluate(plat, sset)
    totals = np.asarray(rep.total_mw)
    mbps = np.asarray(rep.offloaded_mbps)
    p0 = totals[0]                     # empty subset == full offload
    rows = [{
        "on_device": "+".join(subset) if subset else "(none)",
        "total_mw": round(float(p), 1),
        "delta_pct": round(100 * float(p - p0) / float(p0), 2),
        "offload_mbps": round(float(m), 2),
    } for subset, p, m in zip(subsets, totals, mbps)]
    return sorted(rows, key=lambda r: r["total_mw"])


def compression_sweep(compressions=(1, 2, 4, 8, 16, 32, 64, 128),
                      fps_scales=(1, 2, 4, 8, 16, 32), platform=None):
    plat = _plat(platform)
    sset = ScenarioSet.grid(placements=((),),
                            compressions=[float(c) for c in compressions],
                            fps_scales=[float(f) for f in fps_scales],
                            primitives=plat.primitives)
    rep = scenarios.evaluate(plat, sset)
    totals = np.asarray(rep.total_mw)
    mbps = np.asarray(rep.offloaded_mbps)
    rows = []
    for i, (c, f) in enumerate((c, f) for c in compressions
                               for f in fps_scales):
        rows.append({
            "compression": c, "fps_scale": f,
            "offload_mbps": round(float(mbps[i]), 2),
            "total_mw": round(float(totals[i]), 1),
        })
    return rows


def sensitivity(scenario: Scenario | None = None, keys=None, platform=None):
    """d(total)/d(theta_k): mW of system power per unit of coefficient.

    Gradients flow through the batched engine (one reverse pass for the
    whole coefficient set)."""
    plat = _plat(platform)
    sc = scenario or aria2.FULL_ON_DEVICE
    keys = keys or list(aria2.THETA0)
    th0 = {k: jnp.asarray(float(aria2.THETA0[k])) for k in keys}
    sset = ScenarioSet.from_scenarios([sc])
    # R002: total_mw runs host-side placement validation and rebuilds
    # the knob vector on every call; under jax.grad that host work sat
    # inside the traced path.  Validate and build once, differentiate
    # only the device engine eval.
    scenarios._validate(plat, sset)
    eng = scenarios._engine(plat)
    vec = sset.vec()

    def f(th):
        return eng(vec, scenarios._theta(plat, th))["total"][0]

    grads = jax.grad(f)(th0)
    base = float(f(th0))
    rows = [{"theta": k, "value": float(th0[k]),
             "d_total_mw_d_theta": float(grads[k]),
             "elasticity": float(grads[k]) * float(th0[k]) / base}
            for k in keys]
    return sorted(rows, key=lambda r: -abs(r["elasticity"]))


def _non_dominated_dense(pts: np.ndarray) -> np.ndarray:
    """Reference dense dominance filter: ONE (N, N, K) broadcast.

    Exact but O(N^2 K) memory — a 20k-point 3-objective grid allocates
    multi-GB boolean cubes.  Kept as the parity oracle for the blockwise
    `non_dominated` (tests assert mask equality on random grids); all
    production callers go through `non_dominated`."""
    le = (pts[:, None, :] <= pts[None, :, :]).all(-1)   # le[j,i]: q_j <= p_i
    lt = (pts[:, None, :] < pts[None, :, :]).any(-1)    # lt[j,i]: strict
    return ~(le & lt).any(axis=0)


def non_dominated(points, maximize: tuple = (), block: int = 2048
                  ) -> np.ndarray:
    """Boolean mask of Pareto-optimal rows of an (N, K) objective matrix.

    All objectives are minimized; column indices in `maximize` are
    negated first.  Uses the correct dominance test — q dominates p iff
    q <= p in every objective AND q < p in at least one — so points that
    tie on some objectives at better cost in another survive, and exact
    duplicates are all kept (neither strictly dominates).

    Sort-pruned and block-wise: rows are processed in lexicographic order
    (a dominator is componentwise <= with one strict <, so it always
    sorts strictly earlier), each block compared only against the
    already-kept prefix — every dominated point has a *non-dominated*
    dominator by transitivity, so pruning dominated candidates is exact.
    Peak memory is O((front + block) * block * K) instead of the dense
    O(N^2 K) cube, which OOMed on 20k-point joint grids (~10 GB); tie
    semantics are bit-identical to `_non_dominated_dense`.
    """
    pts = np.asarray(points, np.float64).copy()
    if pts.ndim != 2:
        raise ValueError(f"expected (N, K) objectives, got {pts.shape}")
    for c in maximize:
        pts[:, c] *= -1.0
    n = pts.shape[0]
    if n == 0:
        return np.zeros(0, bool)
    order = np.lexsort(pts.T[::-1])         # ascending by col 0, 1, ...
    spts = pts[order]
    keep = np.ones(n, bool)
    for start in range(0, n, block):
        end = min(start + block, n)
        blk = spts[start:end]
        # candidates: surviving strict predecessors + the block itself
        # (intra-block dominators also sort earlier, so one pass suffices)
        cand = np.concatenate([spts[:start][keep[:start]], blk])
        le = (cand[:, None, :] <= blk[None, :, :]).all(-1)
        lt = (cand[:, None, :] < blk[None, :, :]).any(-1)
        keep[start:end] = ~(le & lt).any(axis=0)
    mask = np.empty(n, bool)
    mask[order] = keep
    return mask


def non_dominated_jax(points, maximize: tuple = ()):
    """Jax-native non-dominated mask — `non_dominated` for traced arrays.

    Jit-composable dominance filter over an (N, K) device matrix with
    EXACTLY the numpy filters' tie semantics (q dominates p iff q <= p
    everywhere and q < p somewhere; exact duplicates are all kept), so a
    fused day pipeline extracts the front without leaving the device.
    Sort-pruned like `non_dominated`: rows are lexsorted (column 0
    primary — any dominator sorts strictly earlier), and each row is
    tested only against its strict predecessors, which cuts the
    candidate set of the dense O(N^2 K) comparison in half and makes
    the earlier/later mask the exact dominance direction.  Parity with
    `_non_dominated_dense` is asserted in tests on random grids with
    engineered ties and duplicates."""
    pts = jnp.asarray(points)
    if pts.ndim != 2:
        raise ValueError(f"expected (N, K) objectives, got {pts.shape}")
    n, k = pts.shape
    if n == 0:
        return jnp.zeros(0, bool)
    sign = np.ones(k, pts.dtype if pts.dtype != bool else np.float32)
    for c in maximize:
        sign[c] = -1.0
    pts = pts * sign
    # jnp.lexsort: LAST key is primary, so feed columns k-1 .. 0 —
    # the same ascending-by-col-0-then-1-... order as np.lexsort(pts.T[::-1])
    order = jnp.lexsort([pts[:, c] for c in range(k - 1, -1, -1)])
    spts = pts[order]
    le = (spts[:, None, :] <= spts[None, :, :]).all(-1)  # le[j,i]: q_j<=p_i
    lt = (spts[:, None, :] < spts[None, :, :]).any(-1)
    idx = jnp.arange(n)
    earlier = idx[:, None] < idx[None, :]   # j strictly before i in sort
    dominated = (le & lt & earlier).any(axis=0)
    return jnp.zeros(n, bool).at[order].set(~dominated)


def pareto(compressions=(4, 10, 20, 40), platform=None):
    """Placement x compression -> non-dominated (power, bandwidth) points.

    Row order of `pts` follows ScenarioSet.grid (placement outermost,
    then compression), so labels stay in lockstep with the batch."""
    plat = _plat(platform)
    subsets = all_placements(plat.supported_primitives())
    sset = ScenarioSet.grid(placements=subsets,
                            compressions=[float(c) for c in compressions],
                            fps_scales=(1.0,), primitives=plat.primitives)
    labels = [(sset.on_device(i), float(sset.compression[i]))
              for i in range(len(sset))]
    rep = scenarios.evaluate(plat, sset)
    totals = np.asarray(rep.total_mw)
    mbps = np.asarray(rep.offloaded_mbps)
    pts = [{
        "on_device": "+".join(s) or "(none)",
        "compression": int(c) if float(c).is_integer() else c,
        "total_mw": round(float(totals[i]), 1),
        "offload_mbps": round(float(mbps[i]), 2),
    } for i, (s, c) in enumerate(labels)]
    keep = non_dominated(np.stack([totals, mbps], axis=1), maximize=(1,))
    front = sorted((pts[i] for i in np.flatnonzero(keep)),
                   key=lambda r: r["total_mw"])
    return pts, front


# ---------------------------------------------------------------------------
# joint device+backend co-optimization (the full-system Amdahl argument)
# ---------------------------------------------------------------------------

JOINT_MCS_TIERS = tuple(range(len(MCS_TIERS)))


@dataclass
class JointReport:
    """Joint device+backend design-space evaluation.

    Arrays share the ScenarioSet's leading dim N.  Objectives: device_mw
    (minimize), uplink_mbps (maximize — context-fidelity proxy),
    backend_pods (minimize).  front_mask marks the 3-objective
    non-dominated set; sources records whether each backend stream's
    capacity came from a dry-run artifact or the fallback bound, and
    `breakdown` carries the per-stream pod components + chosen serving
    archs (offload.PodsBreakdown).
    """
    sset: ScenarioSet
    device_mw: np.ndarray           # (N,)
    uplink_mbps: np.ndarray         # (N,)
    backend_pods: np.ndarray        # (N,)
    front_mask: np.ndarray          # (N,) bool
    sources: dict                   # stream -> "dryrun" | "fallback"
    n_users: float
    duty: float
    breakdown: offload.PodsBreakdown | None = None

    def __len__(self) -> int:
        return len(self.sset)

    def objectives(self) -> np.ndarray:
        """(N, 3) matrix [device_mw, uplink_mbps, backend_pods]."""
        return np.stack([self.device_mw, self.uplink_mbps,
                         self.backend_pods], axis=1)

    def front_indices(self) -> np.ndarray:
        return np.flatnonzero(self.front_mask)

    def missing_streams(self) -> list:
        """Fallback-sized streams that actually reach the backend.

        Activity-guarded per design point (a fallback "audio" capacity is
        NOT missing on a grid where every point runs ASR on-device — the
        old whole-set check flagged it spuriously)."""
        if self.breakdown is not None:
            return self.breakdown.missing_streams()
        return offload.missing_streams(self.sources)

    def stream_archs(self) -> dict:
        """stream -> serving arch chosen by min-pods (STREAM_CANDIDATES)."""
        if self.breakdown is not None:
            return dict(self.breakdown.archs)
        return {s: arch for s, (arch, _, _) in
                offload.STREAM_SERVICE.items()}

    def cost_per_day(self) -> dict:
        """Steady-state fleet cost: pods x 24 h -> $ and kgCO2 per day.

        Arrays share the grid's leading dim N (offload.pod_cost)."""
        return offload.pod_cost(self.backend_pods * 24.0)

    def row(self, i: int) -> dict:
        s = self.sset
        cost = offload.pod_cost(float(self.backend_pods[i]) * 24.0)
        out = {
            "index": int(i),
            "on_device": "+".join(s.on_device(i)) or "(none)",
            "compression": float(s.compression[i]),
            "fps_scale": float(s.fps_scale[i]),
            "mcs": MCS_TIERS[int(s.mcs_tier[i])][0],
            "upload_duty": round(float(s.upload_duty[i]), 3),
            "brightness": round(float(s.brightness[i]), 3),
            "device_mw": round(float(self.device_mw[i]), 1),
            "uplink_mbps": round(float(self.uplink_mbps[i]), 2),
            "backend_pods": round(float(self.backend_pods[i]), 1),
            "usd_per_day": round(cost["usd"], 0),
            "kgco2_per_day": round(cost["kgco2"], 0),
        }
        if self.breakdown is not None:
            out["pods_by_stream"] = self.breakdown.row(i)
        return out

    def front_rows(self) -> list:
        rows = [self.row(i) for i in self.front_indices()]
        return sorted(rows, key=lambda r: r["device_mw"])


def joint_pareto(platform=None, placements=None,
                 compressions=scenarios.GRID_COMPRESSIONS,
                 fps_scales=scenarios.GRID_FPS_SCALES,
                 mcs_tiers=JOINT_MCS_TIERS,
                 upload_duties=(1.0,), brightnesses=(0.0,),
                 n_users: float = 1e6, duty: float = 0.35,
                 results_dir=None, theta=None) -> JointReport:
    """Joint device+backend Pareto sweep in one batched pass.

    Default grid: 16 placements x 8 compressions x 6 fps x 3 MCS tiers =
    2304 design points; `upload_duties` and `brightnesses` are
    first-class joint axes on top (VAD gating throttles both the radio
    and backend ingest; brightness trades display power on display
    SKUs), multiplying the grid accordingly — the blockwise
    `non_dominated` scales to those sizes.  The whole grid goes through
    ONE jitted vmap device call (scenarios.evaluate), one vectorized
    fleet-sizing pass (offload.pods_breakdown — capacities come from the
    cached CapacityTable, zero disk reads), and one blockwise dominance
    pass (non_dominated) — no per-point Python loops anywhere on the
    path.
    """
    plat = _plat(platform)
    if placements is None:
        placements = all_placements(plat.supported_primitives())
    sset = ScenarioSet.grid(placements=placements,
                            compressions=[float(c) for c in compressions],
                            fps_scales=[float(f) for f in fps_scales],
                            mcs_tiers=[int(m) for m in mcs_tiers],
                            upload_duties=[float(u) for u in upload_duties],
                            brightnesses=[float(b) for b in brightnesses],
                            primitives=plat.primitives)
    rep = scenarios.evaluate(plat, sset, theta)
    device_mw = np.asarray(rep.total_mw, np.float64)
    uplink = np.asarray(rep.offloaded_mbps, np.float64)
    bd = offload.pods_breakdown(sset, n_users=n_users, duty=duty,
                                results_dir=results_dir)
    objs = np.stack([device_mw, uplink, bd.pods], axis=1)
    mask = non_dominated(objs, maximize=(1,))
    return JointReport(sset, device_mw, uplink, bd.pods, mask, bd.sources,
                       n_users, duty, breakdown=bd)


def _lex_argmin(keys: list, feasible: np.ndarray):
    """Index minimizing keys lexicographically over a feasibility mask."""
    idx = np.flatnonzero(feasible)
    if idx.size == 0:
        return None
    order = np.lexsort(tuple(np.asarray(k)[idx] for k in reversed(keys)))
    return int(idx[order[0]])


def co_optimize(rep: JointReport, pod_budget: float | None = None,
                power_budget_mw: float | None = None,
                usd_budget_per_day: float | None = None) -> dict:
    """Constrained argmins over a joint grid (deterministic tie-breaks).

    * device_optimum            — min device power, backend unconstrained
      (ties broken toward fewer pods, then higher uplink).
    * min_power_under_pod_budget — min device power s.t. pods <= budget.
    * min_pods_under_power_budget — min pods s.t. device power <= budget
      (ties toward lower power, then higher uplink).
    * min_power_under_usd_budget — the pod budget stated in money: min
      device power s.t. the 24 h fleet bill (offload.pod_cost: amortized
      capex + energy) fits `usd_budget_per_day`.
    Infeasible constraints yield None rows.
    """
    ones = np.ones(len(rep), bool)
    out = {"device_optimum": rep.row(_lex_argmin(
        [rep.device_mw, rep.backend_pods, -rep.uplink_mbps], ones))}
    if pod_budget is not None:
        i = _lex_argmin([rep.device_mw, rep.backend_pods, -rep.uplink_mbps],
                        rep.backend_pods <= pod_budget)
        out["pod_budget"] = pod_budget
        out["min_power_under_pod_budget"] = None if i is None else rep.row(i)
    if power_budget_mw is not None:
        i = _lex_argmin([rep.backend_pods, rep.device_mw, -rep.uplink_mbps],
                        rep.device_mw <= power_budget_mw)
        out["power_budget_mw"] = power_budget_mw
        out["min_pods_under_power_budget"] = None if i is None else rep.row(i)
    if usd_budget_per_day is not None:
        usd = rep.cost_per_day()["usd"]
        i = _lex_argmin([rep.device_mw, rep.backend_pods, -rep.uplink_mbps],
                        usd <= usd_budget_per_day)
        out["usd_budget_per_day"] = usd_budget_per_day
        out["min_power_under_usd_budget"] = None if i is None else rep.row(i)
    return out


# ---------------------------------------------------------------------------
# day-in-the-life objectives (core/daysim.py) as first-class DSE
# ---------------------------------------------------------------------------

def day_pareto(platforms=None, designs=None, schedules=None, policies=None,
               engine: str = "fused", **kw):
    """Day-level Pareto front over (time-to-empty h, peak skin °C,
    backend pod-hours).

    Every (platform x design x schedule x policy) combo integrates
    through daysim's ONE vmapped `jax.lax.scan` (battery SoC + 2-node
    thermal RC + throttle hysteresis) and the 3-objective non-dominated
    set is extracted (time-to-empty maximized).  With the default
    `engine="fused"` the whole chain — scenario tables, day scan,
    objectives, dominance filter — runs as one device-resident jitted
    program (`daysim.day_grid(engine="fused")` + `non_dominated_jax`),
    served from daysim's compiled-executable cache so repeat queries of
    the same grid shape do zero tracing and zero host table work.
    `engine="legacy"` is the pre-fusion oracle path: host-cached numpy
    tables, the standalone scan, and the blockwise numpy
    `non_dominated` — kept bit-compatible (front mask and survival
    flags) and parity-tested against the fused program.  Returns the
    `daysim.DayReport` with `front_mask` filled; `report.front_rows()`
    carries $ / kgCO2 via the offload cost model."""
    from . import daysim
    args = {k: v for k, v in (("platforms", platforms),
                              ("designs", designs),
                              ("schedules", schedules),
                              ("policies", policies)) if v is not None}
    if engine == "fused":
        return daysim.day_grid(**args, engine="fused", with_front=True,
                               **kw)
    if engine != "legacy":
        raise ValueError(f"unknown engine {engine!r}; "
                         f"expected 'fused' or 'legacy'")
    rep = daysim.day_grid(**args, **kw)
    rep.front_mask = non_dominated(rep.objectives(), maximize=(0,))
    return rep


def day_pareto_batch(queries, **shared):
    """Batched `day_pareto`: K value-level what-ifs through ONE jitted
    program with a leading query axis.

    `queries` is a sequence of dicts of `day_pareto` grid kwargs
    (axes/values) layered over `shared`; every query must land in the
    same bucketed shape signature (same platforms / schedule lengths /
    combo buckets — value-level deltas only), which is what
    `serving.twin.DesignTwin.query_batch` micro-batches by.  Returns
    one `DayReport` per query, `front_mask` filled, each bit-identical
    to the serial `day_pareto` answer for the same kwargs."""
    from . import daysim
    return daysim.day_grid_batch(list(queries), **shared)


def survives_day(rep=None, skin_limit_c: float = 43.0, **kw):
    """(N,) bool per combo: the cell lasts the whole schedule AND peak
    skin temperature stays under the comfort limit.  Pass an existing
    `DayReport` (from `day_pareto`/`daysim.day_grid`) or kwargs to run
    one."""
    if rep is None:
        rep = day_pareto(**kw)
    elif kw:
        raise TypeError(f"got both a DayReport and grid kwargs "
                        f"{sorted(kw)}; pass one or the other")
    return rep.survives(skin_limit_c)


# ---------------------------------------------------------------------------
# gradient-based design optimization on the unified DesignSpace pytree
# ---------------------------------------------------------------------------

@dataclass
class GradResult:
    """`gradient_descend` output: each restart's BEST-SEEN point along
    its whole trajectory (leading dim R; not the final Adam iterate —
    projected Adam can overshoot late) with the matching losses, plus
    the best point/loss across restarts."""
    space: DesignSpace
    points: dict                    # {knob: (R, ...)}
    losses: np.ndarray              # (R,)
    best_point: dict                # {knob: (...)}  best restart
    best_loss: float
    steps: int

    def restart_points(self) -> list:
        r = len(self.losses)
        return [{k: np.asarray(v)[i] for k, v in self.points.items()}
                for i in range(r)]


def gradient_descend(space: DesignSpace, loss_fn, n_restarts: int = 8,
                     steps: int = 200, lr: float = 0.05, seed: int = 0,
                     init: dict | None = None) -> GradResult:
    """Projected Adam over a DesignSpace point, vmapped multi-restart.

    `loss_fn(point) -> scalar` must be jax-traceable; every Adam update
    evaluates ALL restarts in one vmapped value_and_grad call, and the
    projection (`space.clip`) keeps every leaf inside its declared
    bounds.  Restart 0 starts from `init` when given (so a known-good
    grid point can only be improved on); the rest sample uniformly in
    bounds.  The best point/loss seen over ALL steps and restarts is
    tracked on-device (no per-step host sync)."""
    key = jax.random.key(seed)
    pts = space.uniform_sample(key, n_restarts)
    if init is not None:
        space.validate(init)
        pts = {k: v.at[0].set(jnp.asarray(init[k]))
               for k, v in pts.items()}
    pts = space.clip(pts)
    vg = jax.vmap(jax.value_and_grad(loss_fn))
    state = jax.vmap(design.adam_init)(pts)

    @jax.jit
    def step(carry, _):
        pts, st, best_loss, best_pts = carry
        losses, grads = vg(pts)
        new, st = jax.vmap(design.adam_update,
                           in_axes=(0, 0, 0, None))(pts, grads, st, lr)
        new = space.clip(new)
        better = losses < best_loss
        best_loss = jnp.where(better, losses, best_loss)
        best_pts = jax.tree_util.tree_map(
            lambda b, p: jnp.where(
                better.reshape((-1,) + (1,) * (p.ndim - 1)), p, b),
            best_pts, pts)
        return (new, st, best_loss, best_pts), losses

    init_best = jnp.full((n_restarts,), jnp.inf)
    (pts, _, best_loss, best_pts), _ = jax.lax.scan(
        step, (pts, state, init_best, pts), None, length=steps)
    # one final evaluation so the last projected update also competes
    losses, _ = vg(pts)
    better = losses < best_loss
    best_loss = np.asarray(jnp.where(better, losses, best_loss))
    best_pts = jax.tree_util.tree_map(
        lambda b, p: jnp.where(
            jnp.asarray(better).reshape((-1,) + (1,) * (p.ndim - 1)),
            p, b),
        best_pts, pts)
    i = int(np.argmin(best_loss))
    best = {k: np.asarray(v)[i] for k, v in best_pts.items()}
    return GradResult(space, {k: np.asarray(v) for k, v in
                              best_pts.items()},
                      np.asarray(best_loss), best,
                      float(best_loss[i]), steps)


def sensitivity_map(platform=None, sset: ScenarioSet | None = None,
                    theta=None) -> dict:
    """Per-scenario d(total mW)/d(knob) over a whole grid in ONE vjp.

    Each scenario's total depends only on its own knob row (the engine
    is a vmap), so pulling back a ones-cotangent through
    `scenarios.total_mw_relaxed` yields the exact per-scenario gradient
    rows for every knob simultaneously — (N,) for scalar knobs, (N, 4)
    for placement probabilities, (N, 3) for MCS weights — one reverse
    pass for the entire map, however large the grid.

    The placement column answers "what is the marginal mW of moving
    this primitive on-device for THIS design point" — the paper's Fig 4
    bars, continuously, everywhere on the grid at once."""
    plat = _plat(platform)
    if sset is None:
        sset = ScenarioSet.grid(
            placements=all_placements(plat.supported_primitives()),
            primitives=plat.primitives)
    vec = scenarios.relax_vec(sset)

    def f(v):
        return scenarios.total_mw_relaxed(plat, v, theta)

    total, pull = jax.vjp(f, vec)
    grads = pull(jnp.ones_like(total))[0]
    return {
        "sset": sset,
        "total_mw": np.asarray(total),
        "d_mw_d": {k: np.asarray(g) for k, g in grads.items()},
    }


def sensitivity_rows(sense: dict, top: int = 10) -> list:
    """Human-readable top rows of a `sensitivity_map` (largest placement
    leverage first: the biggest |d mW / d placement prob| anywhere)."""
    sset = sense["sset"]
    pl = sense["d_mw_d"]["placement"]
    lever = np.abs(pl).max(axis=1)
    order = np.argsort(-lever)[:top]
    return [{
        "scenario": sset.label(int(i)),
        "compression": float(sset.compression[i]),
        "fps_scale": float(sset.fps_scale[i]),
        "total_mw": round(float(sense["total_mw"][i]), 1),
        "d_mw_d_placement": {p: round(float(pl[i, j]), 1)
                             for j, p in enumerate(sset.primitives)},
        "d_mw_d_upload_duty": round(
            float(sense["d_mw_d"]["upload_duty"][i]), 1),
        "d_mw_d_fps_scale": round(
            float(sense["d_mw_d"]["fps_scale"][i]), 2),
    } for i in order]


def optimize_policy(platform, design_row, schedule, policy_template,
                    peak_cap_c: float | None = None,
                    n_restarts: int = 6, steps: int = 120,
                    lr: float = 0.08, seed: int = 0,
                    dt_s: float = 60.0, peak_weight: float = 8.0,
                    **day_kw) -> dict:
    """Gradient-optimize ThrottlePolicy trip/clear bands through the
    day-scan (straight-through trip comparisons), then HARD-validate.

    Maximizes the smooth time-to-empty surrogate subject to a softplus
    penalty on skin-time above `peak_cap_c` (default: the template
    policy's own hard peak — "equal peak skin").  The template's
    thresholds seed restart 0, so the optimizer can only improve on the
    grid policy it starts from; every restart's final point is hardened
    back into a `ThrottlePolicy` and re-simulated with the exact
    (non-relaxed) integrator — the returned winner is the best HARD
    time-to-empty among candidates whose hard peak respects the cap.

    `day_kw` accepts any day knob of `daysim.relaxed_day_fn` or
    `daysim.simulate` (standby_mw/battery/thermal/theta/shutdown_c,
    n_users/results_dir, tau/ste_beta_*/soft_alive_*); each is routed
    only to the callee that understands it, unknown keys raise."""
    from . import daysim
    shared = {"standby_mw", "battery", "thermal", "theta", "shutdown_c",
              "n_users", "results_dir"}
    relax_only = {"tau", "ste_beta_c", "ste_beta_soc",
                  "soft_alive_margin", "soft_alive_beta"}
    unknown = set(day_kw) - shared - relax_only
    if unknown:
        raise TypeError(f"optimize_policy: unknown day kwargs "
                        f"{sorted(unknown)}")
    relax_kw = {k: v for k, v in day_kw.items()
                if k in shared | relax_only}
    sim_kw = {k: v for k, v in day_kw.items() if k in shared}
    pol = daysim._resolve(policy_template, daysim.get_policy,
                          daysim.ThrottlePolicy)
    if not pol.actions:
        raise ValueError("policy_template needs throttle actions to tune")
    f = daysim.relaxed_day_fn(platform, schedule, pol, design_row,
                              dt_s=dt_s, **relax_kw)
    space = design.policy_space()
    init = design.policy_point(pol)
    base = daysim.simulate(platform, design_row, schedule, pol, dt_s=dt_s,
                           **sim_kw)
    cap = (float(base.summary["peak_skin_c"]) if peak_cap_c is None
           else float(peak_cap_c))

    def loss(point):
        out = f(point)
        exceed = jnp.mean(jax.nn.softplus(
            (out["t_skin"] - cap) * 4.0) / 4.0)
        return -out["soft_tte_h"] + peak_weight * exceed

    res = gradient_descend(space, loss, n_restarts=n_restarts,
                           steps=steps, lr=lr, seed=seed, init=init)

    def harden(pt) -> daysim.ThrottlePolicy:
        return daysim.ThrottlePolicy(
            f"{pol.name}_grad",
            temp_trip_c=float(pt["temp_trip_c"]),
            temp_clear_c=float(pt["temp_trip_c"] - pt["temp_band_c"]),
            soc_trip=float(pt["soc_trip"]),
            soc_clear=float(min(pt["soc_trip"] + pt["soc_band"], 0.95)),
            actions=pol.actions)

    candidates = []
    for pt in res.restart_points():
        cand = harden(pt)
        tr = daysim.simulate(platform, design_row, schedule, cand,
                             dt_s=dt_s, **sim_kw)
        candidates.append((tr.summary["time_to_empty_h"],
                           tr.summary["peak_skin_c"], cand, pt))
    feasible = [c for c in candidates if c[1] <= cap + 1e-6]
    pool = feasible or candidates
    tte, peak, best_pol, best_pt = max(pool, key=lambda c: c[0])
    return {
        "policy": best_pol,
        "point": {k: float(v) for k, v in best_pt.items()},
        "tte_h": float(tte),
        "peak_skin_c": float(peak),
        "peak_cap_c": cap,
        "feasible": bool(feasible),
        "baseline": {"policy": pol.name,
                     "tte_h": float(base.summary["time_to_empty_h"]),
                     "peak_skin_c": float(base.summary["peak_skin_c"])},
        "gain_h": float(tte - base.summary["time_to_empty_h"]),
        "restarts": n_restarts, "steps": steps,
    }


def platform_ablation(names=None, on_device=(), compression: float = 10.0,
                      fps_scale: float = 1.0) -> list:
    """Registry-driven SKU comparison: evaluate one common scenario row
    across platforms and diff each SKU's component table against the
    first (baseline) entry.

    Placements a SKU cannot run are downshifted to the supported subset
    (the point of an ablation row is what the SKU saves, not a crash)."""
    from . import platform as registry
    if names is None:
        names = registry.names()
    plats = [_plat(n) for n in names]
    base = plats[0]
    rows = []
    for plat in plats:
        placement = tuple(p for p in on_device
                          if p in plat.supported_primitives())
        sset = ScenarioSet.grid(placements=(placement,),
                                compressions=(float(compression),),
                                fps_scales=(float(fps_scale),),
                                primitives=plat.primitives)
        rep = scenarios.evaluate(plat, sset)
        d = platform_diff(base, plat)
        rows.append({
            "platform": plat.name,
            "n_components": len(plat),
            "on_device": "+".join(placement) or "(none)",
            "total_mw": round(float(rep.total_mw[0]), 1),
            "offload_mbps": round(float(rep.offloaded_mbps[0]), 2),
            "vs_baseline": {
                "added": sorted(d["added"]),
                "dropped": sorted(d["dropped"]),
                "changed": sorted(d["changed"]),
                "theta": d["theta"], "raw_mbps": d["raw_mbps"],
            },
        })
    base_mw = rows[0]["total_mw"]
    for r in rows:
        r["delta_mw_vs_baseline"] = round(r["total_mw"] - base_mw, 1)
    return rows


# ---------------------------------------------------------------------------
# fleet-level fronts: population variants over ($/day, survival rate)
# ---------------------------------------------------------------------------

@dataclass
class FleetFront:
    """`fleet_pareto` output: one row per population variant plus the
    non-dominated mask over (autoscaled fleet $/day minimized, survival
    rate maximized — and dropped stream-hours minimized when the sweep
    was priced with an autoscaler, so the front carries the QoS axis)."""
    rows: list
    front_mask: np.ndarray

    def front_rows(self) -> list:
        return [r for r, m in zip(self.rows, self.front_mask) if m]


def fleet_pareto(spec=None, variants=None, n_users: int = 1024, key=0,
                 dt_s: float = 60.0, fleet_size: float = 1e6,
                 n_draws: int = 1, autoscaler=None, ci: float = 0.90,
                 **kw) -> FleetFront:
    """SKU-mix / policy Pareto front at fleet scale: backend $/day vs
    the fraction of users whose device survives the day (vs dropped
    stream-hours, when an `autoscale.AutoscalerSpec` prices the
    lagging fleet).

    Each variant is a `(name, PopulationSpec)` — by default every
    (policy x design) override of `spec` via
    `PopulationSpec.with_overrides` (designs a platform can't place
    on-device keep that archetype's original design).  ONE population
    sample (same key) is reused across variants, so fronts compare
    policy/design choices on the identical fleet, and every variant
    runs through the same sharded `fleet.fleet_day` scan.  Costs are
    the autoscaled diurnal-curve pricing at `fleet_size` users.

    `n_draws > 1` runs the whole sweep as Monte Carlo over the
    population key (`montecarlo.fleet_distribution`, same `key` per
    variant = common random numbers): rows carry mean objectives plus
    `ci`-level `*_lo`/`*_hi` bands, and the front ranks the means."""
    from . import daysim, fleet, montecarlo
    if spec is None:
        spec = fleet.DEFAULT_POPULATION
    if variants is None:
        variants = [(f"{pol}/{row['name']}",
                     spec.with_overrides(f"{spec.name}:{pol}:"
                                         f"{row['name']}",
                                         policy=pol, design=row))
                    for pol in daysim.DEFAULT_POLICIES
                    for row in daysim.DEFAULT_DESIGNS]
    rows = []
    if n_draws > 1:
        for name, vspec in variants:
            dist = montecarlo.fleet_distribution(
                vspec, n_users, n_draws, key, ci=ci,
                autoscaler=autoscaler, dt_s=dt_s,
                fleet_size=fleet_size, **kw)
            sv, cost = dist.survival_rate(), dist.cost()
            usd = cost["autoscaled_usd"]
            row = {
                "variant": name, "n_draws": n_draws,
                "survival_rate": sv["mean"],
                "survival_lo": sv["lo"], "survival_hi": sv["hi"],
                "usd_per_day": usd["mean"],
                "usd_lo": usd["lo"], "usd_hi": usd["hi"],
                "tte_p50_h": dist.tte_quantiles()["p50"]["mean"],
            }
            if autoscaler is not None:
                row["dynamic_usd_per_day"] = cost["dynamic_usd"]["mean"]
                drop = cost["dropped_stream_hours"]
                row["dropped_stream_hours"] = drop["mean"]
                row["dropped_stream_hours_hi"] = drop["hi"]
            rows.append(row)
    else:
        pop = fleet.sample_population(spec, n_users, key)
        for name, vspec in variants:
            vpop = replace(pop, spec=vspec)
            rep = fleet.fleet_day(vpop, dt_s=dt_s,
                                  fleet_size=fleet_size, **kw)
            plan = rep.capacity_plan(autoscaler=autoscaler)
            row = {
                "variant": name,
                "survival_rate": rep.survival_rate(),
                "usd_per_day": plan["autoscaled"]["usd"],
                "peak_usd_per_day": plan["peak_provisioned"]["usd"],
                "kg_co2_per_day": plan["autoscaled"]["kgco2"],
                "peak_pods": plan["peak_pods"],
                "trough_peak_ratio": plan["trough_peak_ratio"],
                "tte_p50_h": plan["tte_quantiles_h"]["p50"],
                "shutdowns": plan["shutdowns"],
            }
            if autoscaler is not None:
                row["dynamic_usd_per_day"] = plan["dynamic"]["usd"]
                row["dropped_stream_hours"] = \
                    plan["dropped_stream_hours"]
            rows.append(row)
    cols = ["usd_per_day", "survival_rate"]
    maximize = (1,)
    if autoscaler is not None:
        cols.append("dropped_stream_hours")
    pts = np.asarray([[r[c] for c in cols] for r in rows])
    return FleetFront(rows, non_dominated(pts, maximize=maximize))
