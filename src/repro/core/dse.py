"""Design-space exploration (§V-B, §VI-B) on the batched scenario engine.

Every sweep below builds ONE `ScenarioSet` and evaluates it through a
single jitted `jax.vmap` device call (scenarios.evaluate) — no Python
per-point loops or `float()` host round-trips on the hot path.

Paper sweeps:
  * placement_sweep      — all 2^4 on/off-device primitive placements
                           (Fig 4 shows 6 of them; we evaluate all 16).
  * compression_sweep    — compression {1..128} x fps {1..32} on the
                           full-offload configuration (Fig 6).

Beyond-paper:
  * grid_sweep           — the full placement x compression x fps grid
                           (>= 768 points) in one call, any platform.
  * sensitivity          — d(total power)/d(theta) via jax.grad through
                           the batched evaluator.
  * pareto               — placement x compression grid -> (power,
                           offload-bandwidth) Pareto front: bandwidth is a
                           proxy for backend context fidelity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import aria2, scenarios
from .aria2 import PRIMITIVES, Scenario
from .platform import PlatformSpec
from .scenarios import ScenarioSet, all_placements


def _plat(platform: PlatformSpec | str | None) -> PlatformSpec:
    if platform is None:
        return aria2.aria2_platform()
    if isinstance(platform, str):
        from . import platform as registry
        aria2.platforms()          # ensure built-ins registered
        return registry.get(platform)
    return platform


def grid_sweep(platform=None, placements=None,
               compressions=scenarios.GRID_COMPRESSIONS,
               fps_scales=scenarios.GRID_FPS_SCALES,
               **knobs) -> scenarios.BatchReport:
    """Full DSE grid (default 16 x 8 x 6 = 768 points) in one device call.

    Default placements are every subset of the primitives the platform
    can actually run on-device (reduced SKUs sweep a smaller grid)."""
    plat = _plat(platform)
    if placements is None:
        placements = all_placements(plat.supported_primitives())
    sset = ScenarioSet.grid(placements=placements,
                            compressions=compressions,
                            fps_scales=fps_scales,
                            primitives=plat.primitives, **knobs)
    return scenarios.evaluate(plat, sset)


def placement_sweep(platform=None):
    plat = _plat(platform)
    subsets = all_placements(plat.supported_primitives())
    sset = ScenarioSet.grid(placements=subsets, compressions=(10.0,),
                            fps_scales=(1.0,), primitives=plat.primitives)
    rep = scenarios.evaluate(plat, sset)
    totals = np.asarray(rep.total_mw)
    mbps = np.asarray(rep.offloaded_mbps)
    p0 = totals[0]                     # empty subset == full offload
    rows = [{
        "on_device": "+".join(subset) if subset else "(none)",
        "total_mw": round(float(p), 1),
        "delta_pct": round(100 * float(p - p0) / float(p0), 2),
        "offload_mbps": round(float(m), 2),
    } for subset, p, m in zip(subsets, totals, mbps)]
    return sorted(rows, key=lambda r: r["total_mw"])


def compression_sweep(compressions=(1, 2, 4, 8, 16, 32, 64, 128),
                      fps_scales=(1, 2, 4, 8, 16, 32), platform=None):
    plat = _plat(platform)
    sset = ScenarioSet.grid(placements=((),),
                            compressions=[float(c) for c in compressions],
                            fps_scales=[float(f) for f in fps_scales],
                            primitives=plat.primitives)
    rep = scenarios.evaluate(plat, sset)
    totals = np.asarray(rep.total_mw)
    mbps = np.asarray(rep.offloaded_mbps)
    rows = []
    for i, (c, f) in enumerate((c, f) for c in compressions
                               for f in fps_scales):
        rows.append({
            "compression": c, "fps_scale": f,
            "offload_mbps": round(float(mbps[i]), 2),
            "total_mw": round(float(totals[i]), 1),
        })
    return rows


def sensitivity(scenario: Scenario | None = None, keys=None, platform=None):
    """d(total)/d(theta_k): mW of system power per unit of coefficient.

    Gradients flow through the batched engine (one reverse pass for the
    whole coefficient set)."""
    plat = _plat(platform)
    sc = scenario or aria2.FULL_ON_DEVICE
    keys = keys or list(aria2.THETA0)
    th0 = {k: jnp.asarray(float(aria2.THETA0[k])) for k in keys}
    sset = ScenarioSet.from_scenarios([sc])

    def f(th):
        return scenarios.total_mw(plat, sset, th)[0]

    grads = jax.grad(f)(th0)
    base = float(f(th0))
    rows = [{"theta": k, "value": float(th0[k]),
             "d_total_mw_d_theta": float(grads[k]),
             "elasticity": float(grads[k]) * float(th0[k]) / base}
            for k in keys]
    return sorted(rows, key=lambda r: -abs(r["elasticity"]))


def pareto(compressions=(4, 10, 20, 40), platform=None):
    """Placement x compression -> non-dominated (power, bandwidth) points."""
    plat = _plat(platform)
    subsets = all_placements(plat.supported_primitives())
    labels = [(s, c) for s in subsets for c in compressions]
    sset = ScenarioSet.grid(placements=subsets,
                            compressions=[float(c) for c in compressions],
                            fps_scales=(1.0,), primitives=plat.primitives)
    rep = scenarios.evaluate(plat, sset)
    totals = np.asarray(rep.total_mw)
    mbps = np.asarray(rep.offloaded_mbps)
    pts = [{
        "on_device": "+".join(s) or "(none)",
        "compression": c,
        "total_mw": round(float(totals[i]), 1),
        "offload_mbps": round(float(mbps[i]), 2),
    } for i, (s, c) in enumerate(labels)]
    front = []
    for p in sorted(pts, key=lambda x: x["total_mw"]):
        if all(p["offload_mbps"] > q["offload_mbps"] for q in front):
            front.append(p)
    return pts, front
