"""Design-space exploration (§V-B, §VI-B) + beyond-paper extensions.

Paper sweeps:
  * placement_sweep      — all 2^4 on/off-device primitive placements
                           (Fig 4 shows 6 of them; we evaluate all 16).
  * compression_sweep    — compression {1..128} x fps {1..32} on the
                           full-offload configuration (Fig 6).

Beyond-paper:
  * sensitivity          — d(total power)/d(theta) via jax.grad: ranks
                           which physical coefficient buys the most power
                           per unit improvement, replacing manual sweeps.
  * pareto               — placement x compression grid -> (power,
                           offload-bandwidth) Pareto front: bandwidth is a
                           proxy for backend context fidelity.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import aria2
from .aria2 import PRIMITIVES, Scenario


def placement_sweep():
    p0 = float(aria2.total_mw(aria2.FULL_OFFLOAD))
    rows = []
    for r in range(len(PRIMITIVES) + 1):
        for subset in itertools.combinations(PRIMITIVES, r):
            p = float(aria2.total_mw(Scenario("dse", subset)))
            rows.append({
                "on_device": "+".join(subset) if subset else "(none)",
                "total_mw": round(p, 1),
                "delta_pct": round(100 * (p - p0) / p0, 2),
                "offload_mbps": round(
                    float(aria2.offloaded_mbps(Scenario("d", subset))), 2),
            })
    return sorted(rows, key=lambda r: r["total_mw"])


def compression_sweep(compressions=(1, 2, 4, 8, 16, 32, 64, 128),
                      fps_scales=(1, 2, 4, 8, 16, 32)):
    rows = []
    for c in compressions:
        for f in fps_scales:
            sc = Scenario("sweep", (), compression=float(c),
                          fps_scale=float(f))
            rows.append({
                "compression": c, "fps_scale": f,
                "offload_mbps": round(float(aria2.offloaded_mbps(sc)), 2),
                "total_mw": round(float(aria2.total_mw(sc)), 1),
            })
    return rows


def sensitivity(scenario: Scenario | None = None, keys=None):
    """d(total)/d(theta_k): mW of system power per unit of coefficient."""
    sc = scenario or aria2.FULL_ON_DEVICE
    keys = keys or list(aria2.THETA0)
    th0 = {k: jnp.asarray(float(aria2.THETA0[k])) for k in keys}

    def f(th):
        return aria2.total_mw(sc, th)

    grads = jax.grad(f)(th0)
    rows = [{"theta": k, "value": float(th0[k]),
             "d_total_mw_d_theta": float(grads[k]),
             "elasticity": float(grads[k] * th0[k] / f(th0))}
            for k in keys]
    return sorted(rows, key=lambda r: -abs(r["elasticity"]))


def pareto(compressions=(4, 10, 20, 40)):
    """Placement x compression -> non-dominated (power, bandwidth) points."""
    pts = []
    for r in range(len(PRIMITIVES) + 1):
        for subset in itertools.combinations(PRIMITIVES, r):
            for c in compressions:
                sc = Scenario("p", subset, compression=float(c))
                pts.append({
                    "on_device": "+".join(subset) or "(none)",
                    "compression": c,
                    "total_mw": round(float(aria2.total_mw(sc)), 1),
                    "offload_mbps": round(float(aria2.offloaded_mbps(sc)), 2),
                })
    front = []
    for p in sorted(pts, key=lambda x: x["total_mw"]):
        if all(p["offload_mbps"] > q["offload_mbps"] for q in front):
            front.append(p)
    return pts, front
