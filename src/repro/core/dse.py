"""Design-space exploration (§V-B, §VI-B) on the batched scenario engine.

Every sweep below builds ONE `ScenarioSet` and evaluates it through a
single jitted `jax.vmap` device call (scenarios.evaluate) — no Python
per-point loops or `float()` host round-trips on the hot path.

Paper sweeps:
  * placement_sweep      — all 2^4 on/off-device primitive placements
                           (Fig 4 shows 6 of them; we evaluate all 16).
  * compression_sweep    — compression {1..128} x fps {1..32} on the
                           full-offload configuration (Fig 6).

Beyond-paper:
  * grid_sweep           — the full placement x compression x fps grid
                           (>= 768 points) in one call, any platform.
  * sensitivity          — d(total power)/d(theta) via jax.grad through
                           the batched evaluator.
  * pareto               — placement x compression grid -> (power,
                           offload-bandwidth) Pareto front: bandwidth is a
                           proxy for backend context fidelity.
  * joint_pareto         — the paper's Amdahl lesson applied end to end:
                           placement x compression x fps x MCS swept in
                           ONE batched device call, each point's
                           offloaded streams mapped to per-stream backend
                           pod counts (offload.pods_breakdown, capacities
                           from the cached CapacityTable), and the
                           3-objective (device mW, uplink Mbps, backend
                           pods) non-dominated front extracted by the
                           blockwise numpy dominance pass.
  * co_optimize          — constrained argmins over the joint grid: min
                           device power under a backend pod budget, and
                           min pods under a device power budget.

All dominance filtering goes through `non_dominated` — the correct
Pareto test (<= in every objective, < in at least one), so points that
tie on one objective at better cost in another are kept.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import aria2, offload, scenarios
from .aria2 import PRIMITIVES, Scenario
from .platform import PlatformSpec, diff as platform_diff
from .scenarios import MCS_TIERS, ScenarioSet, all_placements


def _plat(platform: PlatformSpec | str | None) -> PlatformSpec:
    if platform is None:
        return aria2.aria2_platform()
    if isinstance(platform, str):
        from . import platform as registry
        aria2.platforms()          # ensure built-ins registered
        return registry.get(platform)
    return platform


def grid_sweep(platform=None, placements=None,
               compressions=scenarios.GRID_COMPRESSIONS,
               fps_scales=scenarios.GRID_FPS_SCALES,
               **knobs) -> scenarios.BatchReport:
    """Full DSE grid (default 16 x 8 x 6 = 768 points) in one device call.

    Default placements are every subset of the primitives the platform
    can actually run on-device (reduced SKUs sweep a smaller grid)."""
    plat = _plat(platform)
    if placements is None:
        placements = all_placements(plat.supported_primitives())
    sset = ScenarioSet.grid(placements=placements,
                            compressions=compressions,
                            fps_scales=fps_scales,
                            primitives=plat.primitives, **knobs)
    return scenarios.evaluate(plat, sset)


def placement_sweep(platform=None):
    plat = _plat(platform)
    subsets = all_placements(plat.supported_primitives())
    sset = ScenarioSet.grid(placements=subsets, compressions=(10.0,),
                            fps_scales=(1.0,), primitives=plat.primitives)
    rep = scenarios.evaluate(plat, sset)
    totals = np.asarray(rep.total_mw)
    mbps = np.asarray(rep.offloaded_mbps)
    p0 = totals[0]                     # empty subset == full offload
    rows = [{
        "on_device": "+".join(subset) if subset else "(none)",
        "total_mw": round(float(p), 1),
        "delta_pct": round(100 * float(p - p0) / float(p0), 2),
        "offload_mbps": round(float(m), 2),
    } for subset, p, m in zip(subsets, totals, mbps)]
    return sorted(rows, key=lambda r: r["total_mw"])


def compression_sweep(compressions=(1, 2, 4, 8, 16, 32, 64, 128),
                      fps_scales=(1, 2, 4, 8, 16, 32), platform=None):
    plat = _plat(platform)
    sset = ScenarioSet.grid(placements=((),),
                            compressions=[float(c) for c in compressions],
                            fps_scales=[float(f) for f in fps_scales],
                            primitives=plat.primitives)
    rep = scenarios.evaluate(plat, sset)
    totals = np.asarray(rep.total_mw)
    mbps = np.asarray(rep.offloaded_mbps)
    rows = []
    for i, (c, f) in enumerate((c, f) for c in compressions
                               for f in fps_scales):
        rows.append({
            "compression": c, "fps_scale": f,
            "offload_mbps": round(float(mbps[i]), 2),
            "total_mw": round(float(totals[i]), 1),
        })
    return rows


def sensitivity(scenario: Scenario | None = None, keys=None, platform=None):
    """d(total)/d(theta_k): mW of system power per unit of coefficient.

    Gradients flow through the batched engine (one reverse pass for the
    whole coefficient set)."""
    plat = _plat(platform)
    sc = scenario or aria2.FULL_ON_DEVICE
    keys = keys or list(aria2.THETA0)
    th0 = {k: jnp.asarray(float(aria2.THETA0[k])) for k in keys}
    sset = ScenarioSet.from_scenarios([sc])

    def f(th):
        return scenarios.total_mw(plat, sset, th)[0]

    grads = jax.grad(f)(th0)
    base = float(f(th0))
    rows = [{"theta": k, "value": float(th0[k]),
             "d_total_mw_d_theta": float(grads[k]),
             "elasticity": float(grads[k]) * float(th0[k]) / base}
            for k in keys]
    return sorted(rows, key=lambda r: -abs(r["elasticity"]))


def _non_dominated_dense(pts: np.ndarray) -> np.ndarray:
    """Reference dense dominance filter: ONE (N, N, K) broadcast.

    Exact but O(N^2 K) memory — a 20k-point 3-objective grid allocates
    multi-GB boolean cubes.  Kept as the parity oracle for the blockwise
    `non_dominated` (tests assert mask equality on random grids); all
    production callers go through `non_dominated`."""
    le = (pts[:, None, :] <= pts[None, :, :]).all(-1)   # le[j,i]: q_j <= p_i
    lt = (pts[:, None, :] < pts[None, :, :]).any(-1)    # lt[j,i]: strict
    return ~(le & lt).any(axis=0)


def non_dominated(points, maximize: tuple = (), block: int = 2048
                  ) -> np.ndarray:
    """Boolean mask of Pareto-optimal rows of an (N, K) objective matrix.

    All objectives are minimized; column indices in `maximize` are
    negated first.  Uses the correct dominance test — q dominates p iff
    q <= p in every objective AND q < p in at least one — so points that
    tie on some objectives at better cost in another survive, and exact
    duplicates are all kept (neither strictly dominates).

    Sort-pruned and block-wise: rows are processed in lexicographic order
    (a dominator is componentwise <= with one strict <, so it always
    sorts strictly earlier), each block compared only against the
    already-kept prefix — every dominated point has a *non-dominated*
    dominator by transitivity, so pruning dominated candidates is exact.
    Peak memory is O((front + block) * block * K) instead of the dense
    O(N^2 K) cube, which OOMed on 20k-point joint grids (~10 GB); tie
    semantics are bit-identical to `_non_dominated_dense`.
    """
    pts = np.asarray(points, np.float64).copy()
    if pts.ndim != 2:
        raise ValueError(f"expected (N, K) objectives, got {pts.shape}")
    for c in maximize:
        pts[:, c] *= -1.0
    n = pts.shape[0]
    if n == 0:
        return np.zeros(0, bool)
    order = np.lexsort(pts.T[::-1])         # ascending by col 0, 1, ...
    spts = pts[order]
    keep = np.ones(n, bool)
    for start in range(0, n, block):
        end = min(start + block, n)
        blk = spts[start:end]
        # candidates: surviving strict predecessors + the block itself
        # (intra-block dominators also sort earlier, so one pass suffices)
        cand = np.concatenate([spts[:start][keep[:start]], blk])
        le = (cand[:, None, :] <= blk[None, :, :]).all(-1)
        lt = (cand[:, None, :] < blk[None, :, :]).any(-1)
        keep[start:end] = ~(le & lt).any(axis=0)
    mask = np.empty(n, bool)
    mask[order] = keep
    return mask


def pareto(compressions=(4, 10, 20, 40), platform=None):
    """Placement x compression -> non-dominated (power, bandwidth) points.

    Row order of `pts` follows ScenarioSet.grid (placement outermost,
    then compression), so labels stay in lockstep with the batch."""
    plat = _plat(platform)
    subsets = all_placements(plat.supported_primitives())
    sset = ScenarioSet.grid(placements=subsets,
                            compressions=[float(c) for c in compressions],
                            fps_scales=(1.0,), primitives=plat.primitives)
    labels = [(sset.on_device(i), float(sset.compression[i]))
              for i in range(len(sset))]
    rep = scenarios.evaluate(plat, sset)
    totals = np.asarray(rep.total_mw)
    mbps = np.asarray(rep.offloaded_mbps)
    pts = [{
        "on_device": "+".join(s) or "(none)",
        "compression": int(c) if float(c).is_integer() else c,
        "total_mw": round(float(totals[i]), 1),
        "offload_mbps": round(float(mbps[i]), 2),
    } for i, (s, c) in enumerate(labels)]
    keep = non_dominated(np.stack([totals, mbps], axis=1), maximize=(1,))
    front = sorted((pts[i] for i in np.flatnonzero(keep)),
                   key=lambda r: r["total_mw"])
    return pts, front


# ---------------------------------------------------------------------------
# joint device+backend co-optimization (the full-system Amdahl argument)
# ---------------------------------------------------------------------------

JOINT_MCS_TIERS = tuple(range(len(MCS_TIERS)))


@dataclass
class JointReport:
    """Joint device+backend design-space evaluation.

    Arrays share the ScenarioSet's leading dim N.  Objectives: device_mw
    (minimize), uplink_mbps (maximize — context-fidelity proxy),
    backend_pods (minimize).  front_mask marks the 3-objective
    non-dominated set; sources records whether each backend stream's
    capacity came from a dry-run artifact or the fallback bound, and
    `breakdown` carries the per-stream pod components + chosen serving
    archs (offload.PodsBreakdown).
    """
    sset: ScenarioSet
    device_mw: np.ndarray           # (N,)
    uplink_mbps: np.ndarray         # (N,)
    backend_pods: np.ndarray        # (N,)
    front_mask: np.ndarray          # (N,) bool
    sources: dict                   # stream -> "dryrun" | "fallback"
    n_users: float
    duty: float
    breakdown: offload.PodsBreakdown | None = None

    def __len__(self) -> int:
        return len(self.sset)

    def objectives(self) -> np.ndarray:
        """(N, 3) matrix [device_mw, uplink_mbps, backend_pods]."""
        return np.stack([self.device_mw, self.uplink_mbps,
                         self.backend_pods], axis=1)

    def front_indices(self) -> np.ndarray:
        return np.flatnonzero(self.front_mask)

    def missing_streams(self) -> list:
        """Fallback-sized streams that actually reach the backend.

        Activity-guarded per design point (a fallback "audio" capacity is
        NOT missing on a grid where every point runs ASR on-device — the
        old whole-set check flagged it spuriously)."""
        if self.breakdown is not None:
            return self.breakdown.missing_streams()
        return offload.missing_streams(self.sources)

    def stream_archs(self) -> dict:
        """stream -> serving arch chosen by min-pods (STREAM_CANDIDATES)."""
        if self.breakdown is not None:
            return dict(self.breakdown.archs)
        return {s: arch for s, (arch, _, _) in
                offload.STREAM_SERVICE.items()}

    def cost_per_day(self) -> dict:
        """Steady-state fleet cost: pods x 24 h -> $ and kgCO2 per day.

        Arrays share the grid's leading dim N (offload.pod_cost)."""
        return offload.pod_cost(self.backend_pods * 24.0)

    def row(self, i: int) -> dict:
        s = self.sset
        cost = offload.pod_cost(float(self.backend_pods[i]) * 24.0)
        out = {
            "index": int(i),
            "on_device": "+".join(s.on_device(i)) or "(none)",
            "compression": float(s.compression[i]),
            "fps_scale": float(s.fps_scale[i]),
            "mcs": MCS_TIERS[int(s.mcs_tier[i])][0],
            "upload_duty": round(float(s.upload_duty[i]), 3),
            "brightness": round(float(s.brightness[i]), 3),
            "device_mw": round(float(self.device_mw[i]), 1),
            "uplink_mbps": round(float(self.uplink_mbps[i]), 2),
            "backend_pods": round(float(self.backend_pods[i]), 1),
            "usd_per_day": round(cost["usd"], 0),
            "kgco2_per_day": round(cost["kgco2"], 0),
        }
        if self.breakdown is not None:
            out["pods_by_stream"] = self.breakdown.row(i)
        return out

    def front_rows(self) -> list:
        rows = [self.row(i) for i in self.front_indices()]
        return sorted(rows, key=lambda r: r["device_mw"])


def joint_pareto(platform=None, placements=None,
                 compressions=scenarios.GRID_COMPRESSIONS,
                 fps_scales=scenarios.GRID_FPS_SCALES,
                 mcs_tiers=JOINT_MCS_TIERS,
                 upload_duties=(1.0,), brightnesses=(0.0,),
                 n_users: float = 1e6, duty: float = 0.35,
                 results_dir=None, theta=None) -> JointReport:
    """Joint device+backend Pareto sweep in one batched pass.

    Default grid: 16 placements x 8 compressions x 6 fps x 3 MCS tiers =
    2304 design points; `upload_duties` and `brightnesses` are
    first-class joint axes on top (VAD gating throttles both the radio
    and backend ingest; brightness trades display power on display
    SKUs), multiplying the grid accordingly — the blockwise
    `non_dominated` scales to those sizes.  The whole grid goes through
    ONE jitted vmap device call (scenarios.evaluate), one vectorized
    fleet-sizing pass (offload.pods_breakdown — capacities come from the
    cached CapacityTable, zero disk reads), and one blockwise dominance
    pass (non_dominated) — no per-point Python loops anywhere on the
    path.
    """
    plat = _plat(platform)
    if placements is None:
        placements = all_placements(plat.supported_primitives())
    sset = ScenarioSet.grid(placements=placements,
                            compressions=[float(c) for c in compressions],
                            fps_scales=[float(f) for f in fps_scales],
                            mcs_tiers=[int(m) for m in mcs_tiers],
                            upload_duties=[float(u) for u in upload_duties],
                            brightnesses=[float(b) for b in brightnesses],
                            primitives=plat.primitives)
    rep = scenarios.evaluate(plat, sset, theta)
    device_mw = np.asarray(rep.total_mw, np.float64)
    uplink = np.asarray(rep.offloaded_mbps, np.float64)
    bd = offload.pods_breakdown(sset, n_users=n_users, duty=duty,
                                results_dir=results_dir)
    objs = np.stack([device_mw, uplink, bd.pods], axis=1)
    mask = non_dominated(objs, maximize=(1,))
    return JointReport(sset, device_mw, uplink, bd.pods, mask, bd.sources,
                       n_users, duty, breakdown=bd)


def _lex_argmin(keys: list, feasible: np.ndarray):
    """Index minimizing keys lexicographically over a feasibility mask."""
    idx = np.flatnonzero(feasible)
    if idx.size == 0:
        return None
    order = np.lexsort(tuple(np.asarray(k)[idx] for k in reversed(keys)))
    return int(idx[order[0]])


def co_optimize(rep: JointReport, pod_budget: float | None = None,
                power_budget_mw: float | None = None,
                usd_budget_per_day: float | None = None) -> dict:
    """Constrained argmins over a joint grid (deterministic tie-breaks).

    * device_optimum            — min device power, backend unconstrained
      (ties broken toward fewer pods, then higher uplink).
    * min_power_under_pod_budget — min device power s.t. pods <= budget.
    * min_pods_under_power_budget — min pods s.t. device power <= budget
      (ties toward lower power, then higher uplink).
    * min_power_under_usd_budget — the pod budget stated in money: min
      device power s.t. the 24 h fleet bill (offload.pod_cost: amortized
      capex + energy) fits `usd_budget_per_day`.
    Infeasible constraints yield None rows.
    """
    ones = np.ones(len(rep), bool)
    out = {"device_optimum": rep.row(_lex_argmin(
        [rep.device_mw, rep.backend_pods, -rep.uplink_mbps], ones))}
    if pod_budget is not None:
        i = _lex_argmin([rep.device_mw, rep.backend_pods, -rep.uplink_mbps],
                        rep.backend_pods <= pod_budget)
        out["pod_budget"] = pod_budget
        out["min_power_under_pod_budget"] = None if i is None else rep.row(i)
    if power_budget_mw is not None:
        i = _lex_argmin([rep.backend_pods, rep.device_mw, -rep.uplink_mbps],
                        rep.device_mw <= power_budget_mw)
        out["power_budget_mw"] = power_budget_mw
        out["min_pods_under_power_budget"] = None if i is None else rep.row(i)
    if usd_budget_per_day is not None:
        usd = rep.cost_per_day()["usd"]
        i = _lex_argmin([rep.device_mw, rep.backend_pods, -rep.uplink_mbps],
                        usd <= usd_budget_per_day)
        out["usd_budget_per_day"] = usd_budget_per_day
        out["min_power_under_usd_budget"] = None if i is None else rep.row(i)
    return out


# ---------------------------------------------------------------------------
# day-in-the-life objectives (core/daysim.py) as first-class DSE
# ---------------------------------------------------------------------------

def day_pareto(platforms=None, designs=None, schedules=None, policies=None,
               **kw):
    """Day-level Pareto front over (time-to-empty h, peak skin °C,
    backend pod-hours).

    Every (platform x design x schedule x policy) combo integrates
    through daysim's ONE vmapped `jax.lax.scan` (battery SoC + 2-node
    thermal RC + throttle hysteresis), and the 3-objective non-dominated
    set is extracted with the shared blockwise `non_dominated` filter
    (time-to-empty is maximized).  Returns the `daysim.DayReport` with
    `front_mask` filled; `report.front_rows()` carries $ / kgCO2 via the
    offload cost model."""
    from . import daysim
    args = {k: v for k, v in (("platforms", platforms),
                              ("designs", designs),
                              ("schedules", schedules),
                              ("policies", policies)) if v is not None}
    rep = daysim.day_grid(**args, **kw)
    rep.front_mask = non_dominated(rep.objectives(), maximize=(0,))
    return rep


def survives_day(rep=None, skin_limit_c: float = 43.0, **kw):
    """(N,) bool per combo: the cell lasts the whole schedule AND peak
    skin temperature stays under the comfort limit.  Pass an existing
    `DayReport` (from `day_pareto`/`daysim.day_grid`) or kwargs to run
    one."""
    if rep is None:
        rep = day_pareto(**kw)
    elif kw:
        raise TypeError(f"got both a DayReport and grid kwargs "
                        f"{sorted(kw)}; pass one or the other")
    return rep.survives(skin_limit_c)


def platform_ablation(names=None, on_device=(), compression: float = 10.0,
                      fps_scale: float = 1.0) -> list:
    """Registry-driven SKU comparison: evaluate one common scenario row
    across platforms and diff each SKU's component table against the
    first (baseline) entry.

    Placements a SKU cannot run are downshifted to the supported subset
    (the point of an ablation row is what the SKU saves, not a crash)."""
    from . import platform as registry
    if names is None:
        names = registry.names()
    plats = [_plat(n) for n in names]
    base = plats[0]
    rows = []
    for plat in plats:
        placement = tuple(p for p in on_device
                          if p in plat.supported_primitives())
        sset = ScenarioSet.grid(placements=(placement,),
                                compressions=(float(compression),),
                                fps_scales=(float(fps_scale),),
                                primitives=plat.primitives)
        rep = scenarios.evaluate(plat, sset)
        d = platform_diff(base, plat)
        rows.append({
            "platform": plat.name,
            "n_components": len(plat),
            "on_device": "+".join(placement) or "(none)",
            "total_mw": round(float(rep.total_mw[0]), 1),
            "offload_mbps": round(float(rep.offloaded_mbps[0]), 2),
            "vs_baseline": {
                "added": sorted(d["added"]),
                "dropped": sorted(d["dropped"]),
                "changed": sorted(d["changed"]),
                "theta": d["theta"], "raw_mbps": d["raw_mbps"],
            },
        })
    base_mw = rows[0]["total_mw"]
    for r in rows:
        r["delta_mw_vs_baseline"] = round(r["total_mw"] - base_mw, 1)
    return rows
